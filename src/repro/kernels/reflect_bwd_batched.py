"""Pallas TPU kernels: backwards for the per-tenant bank reflections.

The batched analogues of ``reflect_bwd``: every sequence gathers its
tenant's hyperplane vectors via scalar-prefetch indexing (same indexed
DMA as the forward), computes its tile-local dx, and accumulates a
*per-sequence* un-normalized dL/dû partial over its S tiles.  The bank
cotangent is finished by the ops.py wrapper:

    du_bank = norm_chain(u_bank, zeros.at[ids].add(ĝ_seq))

scatter-add first, ε-normalization chain second — valid because the
chain rule is linear in dL/dû and all sequences with the same tenant id
share one bank row.  This reproduces ref-AD's gather-vjp exactly, so
duplicate tenant ids accumulate rather than overwrite.

Grid: (B, S/block_s).  ĝ_seq rides in a persistent (n, db) f32 scratch,
re-zeroed at each sequence's first S tile and emitted at its last.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.reflect_bwd import reflect_bwd_tile, unit_rows


def _r1b_bwd_kernel(ids_ref, u_ref, x_ref, g_ref, dx_ref, gu_ref,
                    acc_ref, *, n: int, db: int):
    del ids_ref  # consumed by the index maps
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    un = unit_rows(u_ref[0].astype(jnp.float32))
    bs = x_ref.shape[1]
    xb = x_ref[0].astype(jnp.float32).reshape(bs, n, db)
    gb = g_ref[0].astype(jnp.float32).reshape(bs, n, db)
    term, ghat = reflect_bwd_tile(xb, gb, un, -2.0)
    dx_ref[0] = (gb + term).reshape(bs, n * db).astype(dx_ref.dtype)
    acc_ref[...] += ghat

    @pl.when(j == pl.num_programs(1) - 1)
    def _emit():
        gu_ref[0] = acc_ref[...]


def _r2b_bwd_kernel(ids_ref, u_ref, v_ref, x_ref, g_ref, dx_ref, gu_ref,
                    gv_ref, accu_ref, accv_ref, *, n: int, db: int):
    del ids_ref
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        accu_ref[...] = jnp.zeros_like(accu_ref)
        accv_ref[...] = jnp.zeros_like(accv_ref)

    un = unit_rows(u_ref[0].astype(jnp.float32))
    vn = unit_rows(v_ref[0].astype(jnp.float32))
    bs = x_ref.shape[1]
    xb = x_ref[0].astype(jnp.float32).reshape(bs, n, db)
    gb = g_ref[0].astype(jnp.float32).reshape(bs, n, db)
    tu, ghu = reflect_bwd_tile(xb, gb, un, -1.0)
    tv, ghv = reflect_bwd_tile(xb, gb, vn, +1.0)
    dx_ref[0] = (gb + tu + tv).reshape(bs, n * db).astype(dx_ref.dtype)
    accu_ref[...] += ghu
    accv_ref[...] += ghv

    @pl.when(j == pl.num_programs(1) - 1)
    def _emit():
        gu_ref[0] = accu_ref[...]
        gv_ref[0] = accv_ref[...]


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def ether_reflect_batched_bwd_pallas(x: jax.Array, u_bank: jax.Array,
                                     ids: jax.Array, g: jax.Array, *,
                                     block_s: int = 128,
                                     interpret: bool | None = None):
    """x/g: (B, S, d); u_bank: (A, n, db); ids: (B,).
    Returns (dx, ĝ_seq (B, n, db) f32 un-normalized partials)."""
    from repro.core.execute import _interpret, largest_divisor
    b, s, d = x.shape
    _, n, db = u_bank.shape
    assert n * db == d and g.shape == x.shape
    block_s = largest_divisor(s, block_s)
    grid = (b, s // block_s)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, n, db), lambda i, j, ids_ref: (ids_ref[i], 0, 0)),
            pl.BlockSpec((1, block_s, d), lambda i, j, ids_ref: (i, j, 0)),
            pl.BlockSpec((1, block_s, d), lambda i, j, ids_ref: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_s, d), lambda i, j, ids_ref: (i, j, 0)),
            pl.BlockSpec((1, n, db), lambda i, j, ids_ref: (i, 0, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((n, db), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_r1b_bwd_kernel, n=n, db=db),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((b, s, d), x.dtype),
                   jax.ShapeDtypeStruct((b, n, db), jnp.float32)],
        interpret=_interpret(interpret),
    )(ids.astype(jnp.int32), u_bank, x, g)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def etherplus_reflect_batched_bwd_pallas(x: jax.Array, u_bank: jax.Array,
                                         v_bank: jax.Array, ids: jax.Array,
                                         g: jax.Array, *,
                                         block_s: int = 128,
                                         interpret: bool | None = None):
    """Rank-2 bank reflect backward.  Returns (dx, ĝu_seq, ĝv_seq)."""
    from repro.core.execute import _interpret, largest_divisor
    b, s, d = x.shape
    _, n, db = u_bank.shape
    assert n * db == d and u_bank.shape == v_bank.shape
    block_s = largest_divisor(s, block_s)
    grid = (b, s // block_s)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, n, db), lambda i, j, ids_ref: (ids_ref[i], 0, 0)),
            pl.BlockSpec((1, n, db), lambda i, j, ids_ref: (ids_ref[i], 0, 0)),
            pl.BlockSpec((1, block_s, d), lambda i, j, ids_ref: (i, j, 0)),
            pl.BlockSpec((1, block_s, d), lambda i, j, ids_ref: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_s, d), lambda i, j, ids_ref: (i, j, 0)),
            pl.BlockSpec((1, n, db), lambda i, j, ids_ref: (i, 0, 0)),
            pl.BlockSpec((1, n, db), lambda i, j, ids_ref: (i, 0, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((n, db), jnp.float32),
                        pltpu.VMEM((n, db), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_r2b_bwd_kernel, n=n, db=db),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((b, s, d), x.dtype),
                   jax.ShapeDtypeStruct((b, n, db), jnp.float32),
                   jax.ShapeDtypeStruct((b, n, db), jnp.float32)],
        interpret=_interpret(interpret),
    )(ids.astype(jnp.int32), u_bank, v_bank, x, g)
