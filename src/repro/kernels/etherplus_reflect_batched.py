"""Pallas TPU kernel: per-tenant bank gather + rank-2 ETHER+ reflection.

The ETHER+ analogue of ``ether_reflect_batched``: every sequence in the
batch gathers its tenant's (n, db) ``u`` AND ``v`` hyperplane vectors
from the resident ``(A, n, db)`` HBM banks (scalar-prefetch indexed DMA)
and applies the blockwise rank-2 update

    H⁺_B x = x − û(ûᵀx) + v̂(v̂ᵀx)

to that sequence's tokens.  Both projections read the *original* x (a
true rank-2 update, not two sequential reflections — see
core.transforms.etherplus_activation).  Used on the input side of a bank
GEMM and again on the output side (with the u2/v2 banks) for two-sided
ETHER+ serving — this is what makes ``--tenants N --method etherplus``
real.

Grid: (B, S/block_s).  VMEM per step ≈ 2·block_s·d·4B + 2·n·db·4B.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.etherplus_gemm import _rank2_rows


def _ep_reflect_batched_kernel(ids_ref, u_ref, v_ref, x_ref, o_ref, *,
                               n: int, db: int):
    del ids_ref  # consumed by the index maps, not the body
    x = x_ref[0].astype(jnp.float32)                         # (bs, d)
    bs = x.shape[0]
    out = _rank2_rows(x.reshape(bs, n, db),
                      u_ref[0].astype(jnp.float32),
                      v_ref[0].astype(jnp.float32))
    o_ref[0] = out.reshape(bs, n * db).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def etherplus_reflect_batched_pallas(x: jax.Array, u_bank: jax.Array,
                                     v_bank: jax.Array, ids: jax.Array, *,
                                     block_s: int = 128,
                                     interpret: bool | None = None
                                     ) -> jax.Array:
    """x: (B, S, d); u_bank/v_bank: (A, n, db), n*db == d; ids: (B,).

    Returns H⁺_B(ids[b]) x[b] — each sequence rank-2-reflected by its
    own tenant's hyperplane pair."""
    from repro.core.execute import _interpret, largest_divisor
    b, s, d = x.shape
    _, n, db = u_bank.shape
    assert n * db == d and u_bank.shape == v_bank.shape, (n, db, d)
    block_s = largest_divisor(s, block_s)   # odd decode shapes must work
    grid = (b, s // block_s)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, n, db), lambda i, j, ids_ref: (ids_ref[i], 0, 0)),
            pl.BlockSpec((1, n, db), lambda i, j, ids_ref: (ids_ref[i], 0, 0)),
            pl.BlockSpec((1, block_s, d), lambda i, j, ids_ref: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_s, d),
                               lambda i, j, ids_ref: (i, j, 0)),
    )
    return pl.pallas_call(
        functools.partial(_ep_reflect_batched_kernel, n=n, db=db),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, s, d), x.dtype),
        interpret=_interpret(interpret),
    )(ids.astype(jnp.int32), u_bank, v_bank, x)
