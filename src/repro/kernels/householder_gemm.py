"""Pallas TPU kernel: fused reflect-and-matmul ``y = (H_B W)ᵀ x``.

The TPU-native fusion of the paper's §3.4 block-parallel scheme: instead
of materializing the transformed weight (O(d·f) extra HBM traffic per
step, or O(d²f/n) FLOPs in the paper's literal block-GEMM form), the
Householder reflection is applied to the x-tile *inside the GEMM k-loop*,
so transformed weights never exist anywhere — not in HBM, not in VMEM.

Grid: (M/Tm, F/Tf, K/Tk), K innermost for f32 scratch accumulation.
Constraint: Tk % db == 0 (each K-tile holds whole reflection blocks, so
the blockwise projection is tile-local). ops.py enforces/falls back.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _hh_gemm_kernel(u_ref, x_ref, w_ref, o_ref, acc_ref, *, nk: int, db: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    u = u_ref[...].astype(jnp.float32)                       # (nk, db)
    un = u / (jnp.sqrt(jnp.sum(u * u, -1, keepdims=True)) + 1e-8)
    x = x_ref[...].astype(jnp.float32)                       # (Tm, Tk)
    tm, tk = x.shape
    xb = x.reshape(tm, nk, db)
    proj = jnp.einsum("tnb,nb->tn", xb, un)
    xr = (xb - 2.0 * proj[..., None] * un[None]).reshape(tm, tk)
    acc_ref[...] += jax.lax.dot_general(
        xr, w_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_m", "block_f", "block_k",
                                    "interpret"))
def householder_gemm_pallas(x: jax.Array, w: jax.Array, u: jax.Array, *,
                            block_m: int = 128, block_f: int = 128,
                            block_k: int = 512,
                            interpret: bool | None = None) -> jax.Array:
    """x: (T, d); w: (d, f); u: (n, db). Returns reflect(x) @ w.

    interpret=None auto-detects via core.execute._interpret."""
    from repro.core.execute import _interpret
    interpret = _interpret(interpret)
    t, d = x.shape
    d2, f = w.shape
    n, db = u.shape
    assert d == d2 and n * db == d
    block_m = min(block_m, t)
    block_f = min(block_f, f)
    block_k = min(block_k, d)
    # whole blocks per K-tile
    if block_k % db:
        block_k = db * max(1, block_k // db)
    nk = block_k // db
    assert t % block_m == 0 and f % block_f == 0 and d % block_k == 0
    grid = (t // block_m, f // block_f, d // block_k)
    return pl.pallas_call(
        functools.partial(_hh_gemm_kernel, nk=nk, db=db),
        grid=grid,
        in_specs=[
            pl.BlockSpec((nk, db), lambda i, j, k: (k, 0)),
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_f), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_f), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_f), jnp.float32)],
        interpret=interpret,
    )(u, x, w)
