"""Pallas TPU kernels: hand-derived backwards for the token reflections.

Pallas has no autodiff (and interpret mode's AD raises outright), so the
kernel-backed training path needs explicit backward kernels.  ETHER's
multiplicative structure makes them cheap to derive: with û = u/(‖u‖+ε)
and the blockwise generalized update

    y = x + c_u û(ûᵀx) [+ c_v v̂(v̂ᵀx)]            (rank-1: c_u = −2;
                                                   ETHER+: c_u=−1, c_v=+1)

the operator is symmetric, so for a cotangent G:

    dx   = G + c_u û(ûᵀG) [+ c_v v̂(v̂ᵀG)]          (reapply the transform)
    dL/dû = c_u Σ_t [ (ûᵀx_t) G_t + (ûᵀG_t) x_t ]   (and likewise for v̂)
    du   = dL/dû/s − (u·dL/dû) u/(r s²),  r = ‖u‖, s = r + ε

i.e. the backward reuses the forward's normalized directions as its only
residuals — no intermediate activations are saved, and nothing is
re-derived by differentiating the jnp reference.

Grid: (T/block_t,).  dx is tile-local; dL/dû accumulates in a persistent
f32 VMEM scratch across all row tiles (the TPU grid is sequential on a
core) and the ε-normalization chain rule is applied once at the final
step.  VMEM per step ≈ 3·block_t·d·4B + O(d) for the adapter vectors.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def norm_chain(u, ghat, eps: float = 1e-8):
    """Pull dL/dû back through û = u/(‖u‖+ε) on the last axis (f32).

    This is exactly XLA's AD of the reference normalization, so kernel
    backwards that use it agree with ref-AD to rounding error."""
    r = jnp.sqrt(jnp.sum(u * u, axis=-1, keepdims=True))
    s = r + eps
    dot = jnp.sum(u * ghat, axis=-1, keepdims=True)
    return ghat / s - dot * u / (r * s * s)


def unit_rows(u, eps: float = 1e-8):
    """Row-normalize (f32) — matches the forward kernels' û."""
    return u / (jnp.sqrt(jnp.sum(u * u, axis=-1, keepdims=True)) + eps)


def reflect_bwd_tile(xb, gb, un, coeff):
    """Shared per-tile math: (dx_b, ĝ_u) for one rank-1 direction.

    xb/gb: (T, n, db) f32; un: (n, db) unit rows.  Returns the dx
    contribution of this direction *excluding* the identity term and the
    un-normalized dL/dû partial for this tile."""
    pg = jnp.einsum("tnb,nb->tn", gb, un)
    px = jnp.einsum("tnb,nb->tn", xb, un)
    dx_term = coeff * pg[..., None] * un[None]
    ghat = coeff * (jnp.einsum("tn,tnb->nb", px, gb)
                    + jnp.einsum("tn,tnb->nb", pg, xb))
    return dx_term, ghat


def _r1_bwd_kernel(u_ref, x_ref, g_ref, dx_ref, du_ref, acc_ref, *,
                   n: int, db: int, coeff: float):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    u = u_ref[...].astype(jnp.float32)
    un = unit_rows(u)
    tm = x_ref.shape[0]
    xb = x_ref[...].astype(jnp.float32).reshape(tm, n, db)
    gb = g_ref[...].astype(jnp.float32).reshape(tm, n, db)
    dx_term, ghat = reflect_bwd_tile(xb, gb, un, coeff)
    dx_ref[...] = (gb + dx_term).reshape(tm, n * db).astype(dx_ref.dtype)
    acc_ref[...] += ghat

    @pl.when(i == pl.num_programs(0) - 1)
    def _done():
        du_ref[...] = norm_chain(u, acc_ref[...]).astype(du_ref.dtype)


def _r2_bwd_kernel(u_ref, v_ref, x_ref, g_ref, dx_ref, du_ref, dv_ref,
                   accu_ref, accv_ref, *, n: int, db: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        accu_ref[...] = jnp.zeros_like(accu_ref)
        accv_ref[...] = jnp.zeros_like(accv_ref)

    u = u_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    un, vn = unit_rows(u), unit_rows(v)
    tm = x_ref.shape[0]
    xb = x_ref[...].astype(jnp.float32).reshape(tm, n, db)
    gb = g_ref[...].astype(jnp.float32).reshape(tm, n, db)
    dxu, ghu = reflect_bwd_tile(xb, gb, un, -1.0)
    dxv, ghv = reflect_bwd_tile(xb, gb, vn, +1.0)
    dx_ref[...] = (gb + dxu + dxv).reshape(tm, n * db).astype(dx_ref.dtype)
    accu_ref[...] += ghu
    accv_ref[...] += ghv

    @pl.when(i == pl.num_programs(0) - 1)
    def _done():
        du_ref[...] = norm_chain(u, accu_ref[...]).astype(du_ref.dtype)
        dv_ref[...] = norm_chain(v, accv_ref[...]).astype(dv_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def ether_reflect_bwd_pallas(x: jax.Array, u: jax.Array, g: jax.Array, *,
                             block_t: int = 256,
                             interpret: bool | None = None):
    """x/g: (T, d); u: (n, db), n*db == d. Returns (dx, du)."""
    from repro.core.execute import _interpret, largest_divisor
    interpret = _interpret(interpret)
    t, d = x.shape
    n, db = u.shape
    assert n * db == d and g.shape == x.shape
    block_t = largest_divisor(t, block_t)
    grid = (t // block_t,)
    return pl.pallas_call(
        functools.partial(_r1_bwd_kernel, n=n, db=db, coeff=-2.0),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, db), lambda i: (0, 0)),
            pl.BlockSpec((block_t, d), lambda i: (i, 0)),
            pl.BlockSpec((block_t, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_t, d), lambda i: (i, 0)),
            pl.BlockSpec((n, db), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, d), x.dtype),
            jax.ShapeDtypeStruct((n, db), u.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((n, db), jnp.float32)],
        interpret=interpret,
    )(u, x, g)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def etherplus_reflect_bwd_pallas(x: jax.Array, u: jax.Array, v: jax.Array,
                                 g: jax.Array, *, block_t: int = 256,
                                 interpret: bool | None = None):
    """Rank-2 H⁺ backward. x/g: (T, d); u/v: (n, db). → (dx, du, dv)."""
    from repro.core.execute import _interpret, largest_divisor
    interpret = _interpret(interpret)
    t, d = x.shape
    n, db = u.shape
    assert n * db == d and u.shape == v.shape and g.shape == x.shape
    block_t = largest_divisor(t, block_t)
    grid = (t // block_t,)
    return pl.pallas_call(
        functools.partial(_r2_bwd_kernel, n=n, db=db),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, db), lambda i: (0, 0)),
            pl.BlockSpec((n, db), lambda i: (0, 0)),
            pl.BlockSpec((block_t, d), lambda i: (i, 0)),
            pl.BlockSpec((block_t, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_t, d), lambda i: (i, 0)),
            pl.BlockSpec((n, db), lambda i: (0, 0)),
            pl.BlockSpec((n, db), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, d), x.dtype),
            jax.ShapeDtypeStruct((n, db), u.dtype),
            jax.ShapeDtypeStruct((n, db), v.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((n, db), jnp.float32),
                        pltpu.VMEM((n, db), jnp.float32)],
        interpret=interpret,
    )(u, v, x, g)
