"""Pallas TPU kernel: flash attention (online softmax), causal + sliding
window, GQA-aware via index-map head folding (KV never repeated in HBM).

Grid (B, H, nQ, nK), K innermost; running max/denominator/accumulator in
f32 VMEM scratch. VMEM per step ≈ (2·Tq·D + 2·Tk·D + Tq·Tk)·4B —
Tq=Tk=128, D=128 → ~0.4 MB, far under the ~16 MB budget, so block sizes
are MXU-bound (128-aligned), not VMEM-bound.

``q_offset`` supports cached-prefix decode: query row i has absolute
position ``q_offset + i`` against kv positions [0, T).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int | None,
                  q_offset: int, block_q: int, block_k: int):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                      # (Tq, D)
    k = k_ref[0, 0].astype(jnp.float32)                      # (Tk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qi = pl.program_id(2)
    qpos = (q_offset + qi * block_q
            + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0))
    kpos = (ki * block_k
            + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1))
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = (acc_ref[...] * alpha[:, None]
                    + jax.lax.dot_general(
                        p, v_ref[0, 0].astype(jnp.float32),
                        (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(ki == pl.num_programs(3) - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "q_offset",
                                    "block_q", "block_k", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int | None = None,
                           q_offset: int = 0, block_q: int = 128,
                           block_k: int = 128,
                           interpret: bool | None = None) -> jax.Array:
    """q: (B, H, S, D); k/v: (B, Hkv, T, D), H % Hkv == 0.

    interpret=None auto-detects via core.execute._interpret."""
    from repro.core.execute import _interpret
    interpret = _interpret(interpret)
    b, h, s, d = q.shape
    _, hkv, t, _ = k.shape
    assert h % hkv == 0
    rep = h // hkv
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    assert s % block_q == 0 and t % block_k == 0
    scale = float(1.0 / (d ** 0.5))
    grid = (b, h, s // block_q, t // block_k)
    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, q_offset=q_offset,
                          block_q=block_q, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki: (bi, hi // rep, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki: (bi, hi // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
