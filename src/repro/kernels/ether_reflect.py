"""Pallas TPU kernel: block-diagonal Householder reflection of activations.

This is the hot op of the *activation-side* ETHER execution mode
(DESIGN.md §3): ``H_B x = x − 2û(ûᵀx)`` applied blockwise on the feature
dim.  Cost O(tokens·d) — the GEMM that follows consumes the frozen weight
unchanged, so ETHER adds zero weight-side HBM traffic.

Tiling: tokens are tiled by ``block_t`` rows; the full (n, db) hyperplane
bank rides along in VMEM (a few KB — ETHER params are tiny by design).
VMEM per step ≈ 2·block_t·d·4B + n·db·4B; block_t=256, d=8192 → ~16 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _reflect_kernel(u_ref, x_ref, o_ref, *, n: int, db: int):
    u = u_ref[...].astype(jnp.float32)                       # (n, db)
    norm = jnp.sqrt(jnp.sum(u * u, axis=-1, keepdims=True))
    un = u / (norm + 1e-8)
    x = x_ref[...].astype(jnp.float32)                       # (Tm, d)
    tm = x.shape[0]
    xb = x.reshape(tm, n, db)
    proj = jnp.einsum("tnb,nb->tn", xb, un)                  # ûᵀx per block
    out = xb - 2.0 * proj[..., None] * un[None]
    o_ref[...] = out.reshape(tm, n * db).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def ether_reflect_pallas(x: jax.Array, u: jax.Array, *, block_t: int = 256,
                         interpret: bool | None = None) -> jax.Array:
    """x: (T, d) tokens; u: (n, db) with n*db == d. Returns H_B x.

    interpret=None auto-detects: compiled on TPU, emulated elsewhere
    (core.execute._interpret) — direct callers no longer silently run the
    Python interpreter on real hardware.
    """
    from repro.core.execute import _interpret, largest_divisor
    interpret = _interpret(interpret)
    t, d = x.shape
    n, db = u.shape
    assert n * db == d, (n, db, d)
    # Largest divisor of t that is <= block_t: direct callers and odd
    # decode shapes (t not a multiple of 256) must not crash — the grid
    # just gets more, smaller row-tiles.
    block_t = largest_divisor(t, block_t)
    grid = (t // block_t,)
    return pl.pallas_call(
        functools.partial(_reflect_kernel, n=n, db=db),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, db), lambda i: (0, 0)),         # whole bank
            pl.BlockSpec((block_t, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_t, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        interpret=interpret,
    )(u, x)
