"""Pallas TPU kernels: ETHER+ weight absorption W' = H⁺_L W H̃⁺_R.

Merged-deployment counterpart of ``ether_merge`` for the rank-2 variant
(satellite of the fused-GEMM tier): the left kernel applies the blockwise
rank-2 update on the input dim (one grid step = one (db × Tf) tile of W
with its block's u/v pair), the right kernel applies it on the output
dim (one grid step = one (Td × db_out) tile).  O(d·f) each, independent
of n — same accounting as the rank-1 merge ("Identity 2", DESIGN.md §3).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _merge_left_kernel(u_ref, v_ref, w_ref, o_ref):
    u = u_ref[...].astype(jnp.float32)                       # (1, db)
    v = v_ref[...].astype(jnp.float32)
    un = u / (jnp.sqrt(jnp.sum(u * u)) + 1e-8)
    vn = v / (jnp.sqrt(jnp.sum(v * v)) + 1e-8)
    w = w_ref[...].astype(jnp.float32)                       # (db, Tf)
    dot = lambda a: jax.lax.dot_general(
        a, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                  # (1, Tf)
    pu, pv = dot(un), dot(vn)
    o_ref[...] = (w - un[0][:, None] * pu[0][None, :]
                  + vn[0][:, None] * pv[0][None, :]).astype(o_ref.dtype)


def _merge_right_kernel(u_ref, v_ref, w_ref, o_ref):
    u = u_ref[...].astype(jnp.float32)                       # (1, db_out)
    v = v_ref[...].astype(jnp.float32)
    un = u / (jnp.sqrt(jnp.sum(u * u)) + 1e-8)
    vn = v / (jnp.sqrt(jnp.sum(v * v)) + 1e-8)
    w = w_ref[...].astype(jnp.float32)                       # (Td, db_out)
    pu = jnp.sum(w * un, axis=-1, keepdims=True)             # (Td, 1) = Wû
    pv = jnp.sum(w * vn, axis=-1, keepdims=True)
    o_ref[...] = (w - pu * un + pv * vn).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_f", "interpret"))
def etherplus_merge_left_pallas(w: jax.Array, u: jax.Array, v: jax.Array,
                                *, block_f: int = 512,
                                interpret: bool | None = None) -> jax.Array:
    """w: (d, f); u/v: (n, db), n*db == d. Returns H⁺_B w."""
    from repro.core.execute import _interpret, largest_divisor
    interpret = _interpret(interpret)
    d, f = w.shape
    n, db = u.shape
    assert n * db == d and u.shape == v.shape
    # lane-aligned tile when f allows it (TPU requirement); the
    # largest-divisor shrink is an interpret-only escape hatch.
    if f % 512 == 0:
        block_f = min(block_f, 512)
    elif f % 128 == 0:
        block_f = min(block_f, 128)
    else:
        block_f = largest_divisor(f, block_f)
    grid = (n, f // block_f)
    return pl.pallas_call(
        _merge_left_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, db), lambda i, j: (i, 0)),
            pl.BlockSpec((1, db), lambda i, j: (i, 0)),
            pl.BlockSpec((db, block_f), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((db, block_f), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d, f), w.dtype),
        interpret=interpret,
    )(u, v, w)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def etherplus_merge_right_pallas(w: jax.Array, u: jax.Array, v: jax.Array,
                                 *, block_d: int = 256,
                                 interpret: bool | None = None) -> jax.Array:
    """w: (d, f); u/v: (n_out, db_out), n_out*db_out == f. Returns w H̃⁺_B."""
    from repro.core.execute import _interpret, largest_divisor
    interpret = _interpret(interpret)
    d, f = w.shape
    n, db = u.shape
    assert n * db == f and u.shape == v.shape
    block_d = largest_divisor(d, block_d)
    grid = (d // block_d, n)
    return pl.pallas_call(
        _merge_right_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, db), lambda i, j: (j, 0)),
            pl.BlockSpec((1, db), lambda i, j: (j, 0)),
            pl.BlockSpec((block_d, db), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_d, db), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d, f), w.dtype),
        interpret=interpret,
    )(u, v, w)
