"""Pallas TPU kernel: per-tenant bank gather-and-reflect (multi-tenant).

The serving hot op of DESIGN.md §2: every sequence in the batch carries a
tenant id; its (n, db) hyperplane vectors are gathered from the resident
``(num_adapters, n, db)`` HBM bank and the block-diagonal Householder
reflection ``H_B x = x − 2û(ûᵀx)`` is applied to that sequence's tokens.
This is the batched analogue of ``ether_reflect`` — and the reason ETHER
can serve thousands of tenants from one weight set: the bank is a few MB
(O(A·d) floats), the gather is free (scalar-prefetch indexed DMA — the
id picks the bank *block* that is staged into VMEM), and the frozen-GEMM
that follows is tenant-independent.

Grid: (B, S/block_s).  The tenant ids ride in scalar-prefetch memory so
the BlockSpec index map can address the bank by id before the kernel
body runs; each grid step stages one (1, n, db) bank slice and one
(1, block_s, d) token tile.  VMEM per step ≈ 2·block_s·d·4B + n·db·4B.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _reflect_batched_kernel(ids_ref, u_ref, x_ref, o_ref, *, n: int,
                            db: int):
    del ids_ref  # consumed by the index maps, not the body
    u = u_ref[0].astype(jnp.float32)                         # (n, db)
    norm = jnp.sqrt(jnp.sum(u * u, axis=-1, keepdims=True))
    un = u / (norm + 1e-8)
    x = x_ref[0].astype(jnp.float32)                         # (bs, d)
    bs = x.shape[0]
    xb = x.reshape(bs, n, db)
    proj = jnp.einsum("tnb,nb->tn", xb, un)                  # ûᵀx per block
    out = xb - 2.0 * proj[..., None] * un[None]
    o_ref[0] = out.reshape(bs, n * db).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def ether_reflect_batched_pallas(x: jax.Array, u_bank: jax.Array,
                                 ids: jax.Array, *, block_s: int = 128,
                                 interpret: bool | None = None
                                 ) -> jax.Array:
    """x: (B, S, d); u_bank: (A, n, db) with n*db == d; ids: (B,) int32.

    Returns H_B(ids[b]) x[b] — each sequence reflected by its own
    tenant's hyperplanes.
    """
    from repro.core.execute import _interpret
    b, s, d = x.shape
    _, n, db = u_bank.shape
    assert n * db == d, (n, db, d)
    block_s = min(block_s, s)
    assert s % block_s == 0, "caller pads tokens to a multiple of block_s"
    grid = (b, s // block_s)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            # the tenant id selects the bank block staged into VMEM
            pl.BlockSpec((1, n, db), lambda i, j, ids_ref: (ids_ref[i], 0, 0)),
            pl.BlockSpec((1, block_s, d), lambda i, j, ids_ref: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_s, d),
                               lambda i, j, ids_ref: (i, j, 0)),
    )
    return pl.pallas_call(
        functools.partial(_reflect_batched_kernel, n=n, db=db),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, s, d), x.dtype),
        interpret=_interpret(interpret),
    )(ids.astype(jnp.int32), u_bank, x)
