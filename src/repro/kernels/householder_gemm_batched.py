"""Pallas TPU kernel: fused tenant-gather + reflect + GEMM (multi-tenant).

The bank-serving analogue of ``householder_gemm``: every sequence in the
batch carries a tenant id whose (n, db) hyperplane vectors are gathered
from the resident ``(A, n, db)`` HBM bank via scalar-prefetch indexing,
the block-diagonal Householder reflection ``H_B x = x − 2û(ûᵀx)`` is
applied to the x-tile *inside the GEMM k-loop*, and the result feeds the
shared frozen-weight GEMM — so bank serving no longer materializes
reflected activations in HBM (previously: ``ether_reflect_batched``
wrote H_B x back to HBM and a separate XLA GEMM re-read it).

Grid: (B, S/Ts, F/Tf, K/Tk), K innermost for f32 scratch accumulation.
The tenant ids ride in scalar-prefetch SMEM; the bank BlockSpec's index
map addresses the id'd bank rows for the current K-tile, so the gather
is a free indexed DMA.  Constraint: Tk % db == 0 (whole reflection
blocks per K-tile).  VMEM per step ≈ (Ts·Tk + Tk·Tf + 2·Ts·Tf)·4B.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _hh_gemm_batched_kernel(ids_ref, u_ref, x_ref, w_ref, o_ref, acc_ref, *,
                            nk: int, db: int):
    del ids_ref  # consumed by the index maps, not the body
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    u = u_ref[0].astype(jnp.float32)                         # (nk, db)
    un = u / (jnp.sqrt(jnp.sum(u * u, -1, keepdims=True)) + 1e-8)
    x = x_ref[0].astype(jnp.float32)                         # (Ts, Tk)
    ts, tk = x.shape
    xb = x.reshape(ts, nk, db)
    proj = jnp.einsum("tnb,nb->tn", xb, un)
    xr = (xb - 2.0 * proj[..., None] * un[None]).reshape(ts, tk)
    acc_ref[...] += jax.lax.dot_general(
        xr, w_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(3) - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_s", "block_f", "block_k",
                                    "interpret"))
def householder_gemm_batched_pallas(x: jax.Array, w: jax.Array,
                                    u_bank: jax.Array, ids: jax.Array, *,
                                    block_s: int = 128, block_f: int = 128,
                                    block_k: int = 512,
                                    interpret: bool | None = None
                                    ) -> jax.Array:
    """x: (B, S, d); w: (d, f); u_bank: (A, n, db), n*db == d; ids: (B,).

    Returns reflect(x[b], u_bank[ids[b]]) @ w for every sequence b."""
    from repro.core.execute import _interpret, largest_divisor
    b, s, d = x.shape
    d2, f = w.shape
    _, n, db = u_bank.shape
    assert d == d2 and n * db == d, (n, db, d)
    block_s = largest_divisor(s, block_s)   # odd decode shapes must work
    block_f = largest_divisor(f, block_f)
    block_k = min(block_k, d)
    if block_k % db:
        block_k = db * max(1, block_k // db)
    nk = block_k // db
    assert d % block_k == 0, "caller guarantees whole K-blocks (ops.py)"
    grid = (b, s // block_s, f // block_f, d // block_k)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            # the tenant id selects the bank rows for this K-tile
            pl.BlockSpec((1, nk, db),
                         lambda i, j, jf, k, ids_ref: (ids_ref[i], k, 0)),
            pl.BlockSpec((1, block_s, block_k),
                         lambda i, j, jf, k, ids_ref: (i, j, k)),
            pl.BlockSpec((block_k, block_f),
                         lambda i, j, jf, k, ids_ref: (k, jf)),
        ],
        out_specs=pl.BlockSpec((1, block_s, block_f),
                               lambda i, j, jf, k, ids_ref: (i, j, jf)),
        scratch_shapes=[pltpu.VMEM((block_s, block_f), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_hh_gemm_batched_kernel, nk=nk, db=db),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, s, f), x.dtype),
        interpret=_interpret(interpret),
    )(ids.astype(jnp.int32), u_bank, x, w)
