"""Mesh/policy context: models stay parallelism-agnostic and read the
active sharding policy from here (set by the launcher / dry-run inside
``with mesh:``). When no context is set (unit tests, single device) every
hook is a no-op.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CURRENT: list["MeshContext"] = []


@dataclasses.dataclass(frozen=True)
class MeshContext:
    mesh: Mesh
    # activation sharding policy
    seq_shard: bool = True          # Megatron-style sequence-sharded residual
    act_embed_shard: bool = False   # shard d_model of activations instead
    # §Perf B1: constrain q/k/v to head-sharded full-sequence layout so
    # attention runs collective-free per head shard (gather at entry,
    # scatter at exit — instead of XLA's per-chunk all-reduces)
    head_shard_attn: bool = True
    # §Perf C1: store attention logits/probs in bf16 (softmax stats in
    # f32) — halves the dominant memory-bound elementwise traffic
    attn_probs_bf16: bool = False
    # §Perf A1: shard_map all-to-all MoE dispatch (vs GSPMD-partitioned
    # global sort, which lowers to full-buffer all-reduces)
    moe_a2a: bool = True

    @property
    def dp_axes(self) -> tuple[str, ...]:
        return tuple(a for a in ("pod", "data") if a in self.mesh.axis_names)

    @property
    def dp_size(self) -> int:
        n = 1
        for a in self.dp_axes:
            n *= self.mesh.shape[a]
        return n

    @property
    def model_size(self) -> int:
        return self.mesh.shape.get("model", 1)


def get_context() -> Optional[MeshContext]:
    return _CURRENT[-1] if _CURRENT else None


@contextlib.contextmanager
def mesh_context(ctx: MeshContext):
    _CURRENT.append(ctx)
    try:
        with ctx.mesh:
            yield ctx
    finally:
        _CURRENT.pop()


def shard_hidden(x: jax.Array) -> jax.Array:
    """Residual-stream constraint: (B→dp, S→model, d) when divisible —
    Megatron sequence parallelism. This is what keeps 62-layer scan
    carries at ~3.5 GB/chip instead of ~57 GB for deepseek-33b train_4k
    (DESIGN.md §4)."""
    ctx = get_context()
    if ctx is None or x.ndim != 3:
        return x
    b, s, _ = x.shape
    bspec = ctx.dp_axes if b % ctx.dp_size == 0 and b > 1 else None
    sspec = ("model" if ctx.seq_shard and s % ctx.model_size == 0 and s > 1
             else None)
    dspec = ("model" if ctx.act_embed_shard and not sspec else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(bspec, sspec, dspec)))


def shard_moe_buffer(x: jax.Array) -> jax.Array:
    """Expert dispatch buffer (E, C, d): E→model (EP), C→dp."""
    ctx = get_context()
    if ctx is None or x.ndim != 3:
        return x
    e, c, _ = x.shape
    espec = "model" if e % ctx.model_size == 0 else None
    cspec = ctx.dp_axes if c % ctx.dp_size == 0 else None
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(espec, cspec, None)))


def shard_heads(x: jax.Array, role: str = "q") -> jax.Array:
    """(B, H, S, D) attention operand layout for collective-free
    attention: B→dp; heads→model when divisible. Fallbacks when H is not
    a multiple of the model axis: queries shard the *sequence* dim (each
    chip owns its q rows against full K/V); small GQA K/V replicate
    (a few hundred MB at most — the GQA win)."""
    ctx = get_context()
    if ctx is None or x.ndim != 4 or not ctx.head_shard_attn:
        return x
    b, h, s, _ = x.shape
    m = ctx.model_size
    bspec = ctx.dp_axes if b % ctx.dp_size == 0 and b > 1 else None
    if h % m == 0:
        # clean TP: whole attention local to a head shard
        spec = P(bspec, "model", None, None)
    elif role == "out" and s % m == 0 and s > 1:
        # §Perf B4: non-divisible heads — three measured dead ends
        # (seq-sharded q / padded head-shard / replicated KV all grew
        # HBM or link, see EXPERIMENTS §Perf B). Only the attention
        # *output* is constrained back to the sequence-sharded residual
        # layout, turning the partial-T psum into a reduce-scatter.
        spec = P(bspec, None, "model", None)
    else:
        return x                                  # leave to GSPMD
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh,
                                                             spec))


_SLOT_CACHE_KINDS = {"k": "kv", "v": "kv", "ssm": "ssm", "conv": "conv",
                     "h": "h"}


def shard_slot_cache(x: jax.Array, kind: Optional[str]) -> jax.Array:
    """Decode-path slot-cache constraint mirroring
    :func:`repro.parallel.sharding.spec_for_cache`: the slot/batch dim
    goes to the data axes (replica-parallel slot groups — decode is
    independent along slots, so dp shards never exchange cache rows) and
    one inner dim goes to ``model`` when divisible.  Applied to the
    cache leaves the serve engine's jitted steps write, so the updated
    cache leaves keep the layout the engine committed them with (a
    layout drift here would change the jit input signature next step —
    a retrace).  No-op without an active context or for unknown
    ``kind``."""
    ctx = get_context()
    if ctx is None or kind not in _SLOT_CACHE_KINDS.values():
        return x
    m = ctx.model_size

    def bspec(b):
        return ctx.dp_axes if b % ctx.dp_size == 0 and b > 1 else None

    if kind == "kv" and x.ndim == 4:                 # (B, kv, T, hd)
        b, kv, t, hd = x.shape
        if kv % m == 0:
            spec = P(bspec(b), "model", None, None)
        elif t % m == 0:
            spec = P(bspec(b), None, "model", None)
        elif hd % m == 0:
            spec = P(bspec(b), None, None, "model")
        else:
            spec = P(bspec(b), None, None, None)
    elif kind == "ssm" and x.ndim == 4:              # (B, H, N, P)
        b, h = x.shape[0], x.shape[1]
        spec = P(bspec(b), "model" if h % m == 0 else None, None, None)
    elif kind == "conv" and x.ndim == 3:             # (B, W-1, C)
        b, c = x.shape[0], x.shape[2]
        spec = P(bspec(b), None, "model" if c % m == 0 else None)
    elif kind == "h" and x.ndim == 2:                # (B, D)
        b, d = x.shape
        spec = P(bspec(b), "model" if d % m == 0 else None)
    else:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh,
                                                             spec))


def attn_probs_dtype(default):
    ctx = get_context()
    if ctx is not None and ctx.attn_probs_bf16:
        import jax.numpy as jnp
        return jnp.bfloat16
    return default


def shard_logits(x: jax.Array) -> jax.Array:
    """(B, S, V): B→dp, V→model (vocab-parallel CE)."""
    ctx = get_context()
    if ctx is None or x.ndim != 3:
        return x
    b = x.shape[0]
    bspec = ctx.dp_axes if b % ctx.dp_size == 0 and b > 1 else None
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(bspec, None, "model")))
