"""Pipeline parallelism (optional runtime): GPipe-style microbatched
stage pipeline on shard_map + ppermute.

Scope (DESIGN.md §4): the production meshes here use DP/FSDP × TP(+EP) —
for PEFT finetuning there is no optimizer-state memory pressure, so
scan-over-layers + FSDP covers the memory story without pipeline
bubbles. This module exists for the full-finetune/pretraining regime and
as the compiled-tested building block for a `pp` mesh axis.

Model contract: the network is a chain of S stage functions with
identical (B_micro, ...) -> (B_micro, ...) activation signatures; stage
s's parameters live on pipeline rank s (sharded over the ``stage`` mesh
axis). The schedule runs M microbatches through S stages in S+M−1 ticks
(GPipe); each tick every rank computes its resident microbatch and
ppermutes the activations forward.

    y = pipeline_apply(stage_fn, stage_params, x, mesh, n_micro=M,
                       stage_axis="stage")
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:
    from jax.experimental.shard_map import shard_map
except ImportError:                                  # newer jax
    from jax.shard_map import shard_map              # type: ignore


def pipeline_apply(stage_fn: Callable, stage_params: Any, x: jax.Array,
                   mesh, *, n_micro: int, stage_axis: str = "stage"):
    """Run x (B, ...) through S = mesh.shape[stage_axis] stages.

    stage_params: pytree whose leaves have a leading S dim (stage-major).
    stage_fn(params_slice, h, stage_index) -> h. B % n_micro == 0.
    """
    S = mesh.shape[stage_axis]
    B = x.shape[0]
    assert B % n_micro == 0, (B, n_micro)
    mb = B // n_micro

    def body(params_local, x_local):
        # params_local: stage slice (1, ...) on this rank; x_local: the
        # full batch replicated along the stage axis (inputs are cheap;
        # a production variant feeds rank 0 only).
        params_me = jax.tree_util.tree_map(lambda a: a[0], params_local)
        rank = jax.lax.axis_index(stage_axis)
        micro = x_local.reshape(n_micro, mb, *x_local.shape[1:])
        n_ticks = S + n_micro - 1

        perm = [(i, (i + 1) % S) for i in range(S)]

        def tick(carry, t):
            buf, outs = carry            # buf: activation resident here
            # which microbatch is at this rank at tick t: m = t - rank
            m = t - rank
            active = (m >= 0) & (m < n_micro)
            # rank 0 ingests microbatch m at tick t
            inject = jnp.where(m >= 0, jnp.clip(m, 0, n_micro - 1), 0)
            h_in = jnp.where(rank == 0, micro[inject], buf)
            h_out = stage_fn(params_me, h_in, rank)
            h_out = jnp.where(active, h_out, buf)
            # last stage banks its finished microbatch
            done = active & (rank == S - 1)
            outs = jax.lax.cond(
                done,
                lambda o: jax.lax.dynamic_update_slice_in_dim(
                    o, h_out[None], jnp.clip(m, 0, n_micro - 1), axis=0),
                lambda o: o, outs)
            # shift activations to the next stage
            buf = jax.lax.ppermute(h_out, stage_axis, perm)
            return (buf, outs), ()

        buf0 = jnp.zeros_like(micro[0])
        outs0 = jnp.zeros_like(micro)
        (_, outs), _ = jax.lax.scan(tick, (buf0, outs0),
                                    jnp.arange(n_ticks))
        # outs is populated only on the last rank; broadcast via psum of
        # the masked buffer (ppermute can't fan out 1→S)
        outs = jax.lax.psum(
            jnp.where(rank == S - 1, outs, jnp.zeros_like(outs)),
            stage_axis)
        return outs.reshape(B, *x_local.shape[1:])

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(stage_axis), P()),
        out_specs=P(),
        check_rep=False)
    return fn(stage_params, x)
