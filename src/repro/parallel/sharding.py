"""Logical sharding rules: (leaf path, shape, mesh) → PartitionSpec.

One rules table covers params, adapters, optimizer states (their leaf
paths end with the same module/kernel names), KV/SSM caches, and input
batches, across every architecture in the zoo.  Scheme (DESIGN.md §4):

* FSDP over the data axes (``("pod","data")`` when multi-pod) on the
  weight dim that matches the activation contraction;
* TP over ``model`` on heads / d_ff / vocab (flattened head dims, so
  GQA KV projections shard evenly even when n_kv < model parallelism);
* EP: MoE expert banks (and their per-expert ETHER adapters) put the
  expert dim on ``model``;
* adapters are replicated by default — they are the ~0.01% trainable
  fraction, and replication makes their DP gradient all-reduce the only
  cross-pod traffic in PEFT training;
* caches: batch→dp; KV heads→model when divisible, else head_dim→model;
* batch arrays: leading batch dim → dp (skipped when B == 1, e.g.
  long_500k, instead of padding a 16× waste).

Rules are *functions of shape*, so a checkpoint written on one mesh can
be restored onto any other (runtime/elastic.py).
"""

from __future__ import annotations

import re
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.pytree import map_with_paths


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _sizes(mesh: Mesh):
    dpx = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dpx])) if dpx else 1
    model = mesh.shape.get("model", 1)
    return dpx, dp_size, model


# ---------------------------------------------------------------------------
# Parameter / optimizer-state rules
# ---------------------------------------------------------------------------

_IN_PROJ = re.compile(
    r"(q_proj|k_proj|v_proj|gate_proj|up_proj|in_proj|in_x|in_y|mm_proj/up_proj"
    r"|router)/kernel$")
_ATTN_QKV = re.compile(r"(q_proj|k_proj|v_proj)/kernel$")
_OUT_PROJ = re.compile(
    r"(o_proj|down_proj|out_proj|mm_proj/down_proj)/kernel$")
_EXPERT = re.compile(r"(gate_proj|up_proj|down_proj)/kernel$")


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def _pick(shape: tuple[int, ...], candidates, mesh: Mesh) -> P:
    """First candidate (right-aligned spec tuple) where every sharded
    dim is divisible by its axis size — pjit rejects uneven shardings."""
    nd = len(shape)
    for cand in candidates:
        cand = cand[-nd:] if len(cand) > nd else cand
        dims = shape[nd - len(cand):]
        if all(d % _axis_size(mesh, e) == 0 for d, e in zip(dims, cand)):
            return P(*([None] * (nd - len(cand)) + list(cand)))
    return P()


def spec_for_param(path: str, shape: tuple[int, ...], mesh: Mesh,
                   serve: bool = False) -> P:
    """PartitionSpec for a parameter-like leaf (params, adapter, opt
    moments — the trailing path components decide). Every rule is a
    preference list; the first divisibility-satisfying layout wins.

    ``serve=True`` (§Perf D): drop FSDP — weights shard over ``model``
    only and replicate across dp, so decode never all-gathers the model
    per token. Exception: 4-D MoE expert banks keep dp sharding (a 235B
    expert bank does not fit per-chip under EP alone)."""
    dpx, dp_size, model = _sizes(mesh)
    nd = len(shape)
    dp = dpx if dpx else None
    if serve and nd < 4:
        dp = None

    def pick(*cands):
        return _pick(shape, cands, mesh)

    if nd == 0 or (not dpx and model == 1):
        return P()
    if path.endswith("embed/table"):                 # (V, d)
        return pick(("model", dp), (None, dp), (None, "model"))
    if path.endswith("pos_embed"):                   # (T, d)
        return pick((None, dp), (None, "model"))
    if path.endswith("lm_head/kernel"):              # (d, V)
        return pick((dp, "model"), (None, "model"), (dp, None))
    # MoE expert banks: (L, E, d_in, d_out) — expert dim on model (EP)
    if _EXPERT.search(path) and nd == 4 and not path.startswith("rem"):
        if "down_proj" in path:
            return pick((None, "model", None, dp), (None, "model", None, None))
        return pick((None, "model", dp, None), (None, "model", None, None))
    if "gate_a/kernel" in path or "gate_x/kernel" in path:
        return pick(("model", None, None))           # (.., H, hd, hd)
    if path.endswith("conv/kernel"):
        return pick((None, "model"))                 # (.., W, C)
    if path.endswith("conv/bias"):
        return pick(("model",))
    if serve and _ATTN_QKV.search(path):
        # DESIGN.md §14: attention in-projections replicate in serve.
        # Their output axis is head-structured, and a model split that
        # crosses head boundaries (n_kv as low as 1 in the zoo) forces
        # a resharding head reshape that XLA CPU miscomputes on 2-D
        # meshes when the data axis is idle (batch-1 prefill).  o_proj
        # and the MLP carry the model axis instead.
        return P()
    if _OUT_PROJ.search(path):                       # (.., d_proj, d)
        return pick(("model", dp), ("model", None), (None, dp))
    if _IN_PROJ.search(path):                        # (.., d, d_proj)
        return pick((dp, "model"), (None, "model"), (dp, None))
    if path.endswith("/lam") or path.endswith("a_log") \
            or path.endswith("dt_bias") or path.endswith("d_skip"):
        return P()
    # adapters: replicate, except per-expert stacks (L, E, n, db) which
    # co-locate with the EP axis
    if re.search(r"/(u|u1|v1|u2|v2|a|b|r|m|d_vec|b_vec|seed)$", path):
        if nd == 4:
            return pick((None, "model", None, None))
        return P()
    if path.endswith("kernel") and nd >= 2:          # generic dense
        return pick((dp, "model"), (None, "model"), (dp, None))
    return P()                                       # norms, biases, scalars


# ---------------------------------------------------------------------------
# Cache rules
# ---------------------------------------------------------------------------

def spec_for_cache(path: str, shape: tuple[int, ...], mesh: Mesh) -> P:
    dpx, dp_size, model = _sizes(mesh)
    nd = len(shape)
    dp = dpx if dpx else None

    def tail(*spec):
        return P(*([None] * (nd - len(spec)) + list(spec)))

    if nd == 0:
        return P()
    base = path.rsplit("/", 1)[-1]
    if base in ("k", "v"):                           # (.., B, kv, T, hd)
        b, kv, t, hd = shape[-4:]
        bspec = dp if b % max(dp_size, 1) == 0 and b > 1 else None
        if kv % model == 0:
            return tail(bspec, "model", None, None)
        if t % model == 0:
            # §Perf D2: T-sharded cache — decode attends via partial
            # logits + tiny softmax psums instead of gathering the
            # hd-sharded cache per layer (10.7→<1 GB/chip temps).
            return tail(bspec, None, "model", None)
        if hd % model == 0:
            return tail(bspec, None, None, "model")
        return tail(bspec, None, None, None)
    if base == "ssm":                                # (.., B, H, N, P)
        b, h = shape[-4], shape[-3]
        bspec = dp if b % max(dp_size, 1) == 0 and b > 1 else None
        hspec = "model" if h % model == 0 else None
        return tail(bspec, hspec, None, None)
    if base == "conv":                               # (.., B, W-1, C)
        b, _, c = shape[-3:]
        bspec = dp if b % max(dp_size, 1) == 0 and b > 1 else None
        cspec = "model" if c % model == 0 else None
        return tail(bspec, None, cspec)
    if base == "h":                                  # (.., B, D)
        b, d = shape[-2:]
        bspec = dp if b % max(dp_size, 1) == 0 and b > 1 else None
        dspec = "model" if d % model == 0 else None
        return tail(bspec, dspec)
    return P()


# ---------------------------------------------------------------------------
# Batch rules
# ---------------------------------------------------------------------------

def spec_for_batch(path: str, shape: tuple[int, ...], mesh: Mesh) -> P:
    dpx, dp_size, _ = _sizes(mesh)
    nd = len(shape)
    if nd == 0 or not dpx:
        return P()
    b = shape[0]
    bspec = dpx if b % dp_size == 0 and b > 1 else None
    return P(*([bspec] + [None] * (nd - 1)))


# ---------------------------------------------------------------------------
# Tree-level helpers
# ---------------------------------------------------------------------------

def _tree_specs(tree: Any, mesh: Mesh, rule) -> Any:
    return map_with_paths(lambda p, l: rule(p, tuple(l.shape), mesh), tree)


def param_specs(tree, mesh, serve: bool = False):
    return _tree_specs(
        tree, mesh,
        lambda p, s, m: spec_for_param(p, s, m, serve=serve))


def cache_specs(tree, mesh):
    return _tree_specs(tree, mesh, spec_for_cache)


def batch_specs(tree, mesh):
    return _tree_specs(tree, mesh, spec_for_batch)


def to_shardings(spec_tree, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
