from repro.runtime.straggler import StepTimer
from repro.runtime.compression import (
    ef_int8_compress,
    ef_int8_decompress,
    compressed_psum,
    init_ef_state,
)
from repro.runtime.elastic import best_mesh_shape, remesh

__all__ = ["StepTimer", "ef_int8_compress", "ef_int8_decompress",
           "compressed_psum", "init_ef_state", "best_mesh_shape", "remesh"]
