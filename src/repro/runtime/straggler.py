"""Straggler / anomaly detection for the train loop.

On a real pod, SPMD steps are globally synchronous — a straggling host
shows up as a slow *global* step. The watchdog tracks an EMA + variance
of step wall-times, flags outliers (> mean + k·σ and > abs_floor), and
invokes a pluggable callback (log, checkpoint-now, or trigger elastic
rebalance). Detection is host-side and free — no device sync beyond the
one the loop already does on metrics.
"""

from __future__ import annotations

import time
from typing import Callable, Optional


class StepTimer:
    def __init__(self, *, ema: float = 0.9, k_sigma: float = 3.0,
                 warmup_steps: int = 5, abs_floor_s: float = 0.05,
                 on_straggler: Optional[Callable[[int, float, float], None]]
                 = None):
        self.ema = ema
        self.k = k_sigma
        self.warmup = warmup_steps
        self.abs_floor = abs_floor_s
        self.on_straggler = on_straggler
        self.mean = 0.0
        self.var = 0.0
        self.n = 0
        self.anomalies: list[tuple[int, float]] = []
        self._t0: Optional[float] = None

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> float:
        assert self._t0 is not None
        dt = time.perf_counter() - self._t0
        self._t0 = None
        self.n += 1
        if self.n <= self.warmup:
            self.mean = dt if self.n == 1 else \
                (self.mean * (self.n - 1) + dt) / self.n
            return dt
        std = self.var ** 0.5
        if dt > max(self.mean + self.k * std, self.mean + self.abs_floor):
            self.anomalies.append((step, dt))
            if self.on_straggler:
                self.on_straggler(step, dt, self.mean)
        d = dt - self.mean
        self.mean += (1 - self.ema) * d
        self.var = self.ema * (self.var + (1 - self.ema) * d * d)
        return dt
