"""The production train loop: sharded step, async checkpoints,
auto-resume, preemption handling, straggler watchdog, failure injection.

This is the engine behind launch/train.py and the fault-tolerance tests:
    trainer = Trainer(cfg, peft, opt, mesh=mesh, ckpt_dir=...)
    trainer.fit(stream, steps=500)

Fault-tolerance contract:
* every ``ckpt_every`` steps the full state (adapters + opt + data cursor
  + step) is snapshotted asynchronously and atomically;
* SIGTERM/SIGINT (preemption) → synchronous checkpoint, clean exit;
* on construction with ``restore='auto'`` the latest checkpoint is
  loaded and the data stream resumes at the exact step;
* ``fail_at_step`` raises mid-run (tests use it to prove restart works);
* the StepTimer flags straggler steps (see runtime/straggler.py).
"""

from __future__ import annotations

import json
import os
import signal
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager, latest_step
from repro.core.transforms import PEFTConfig
from repro.data.pipeline import DataState
from repro.launch.steps import (abstract_state, batch_shardings, init_state,
                                make_train_step, state_shardings)
from repro.optim import GradientTransformation
from repro.parallel.context import MeshContext, mesh_context

Params = dict[str, Any]


class Trainer:
    def __init__(self, cfg, peft: Optional[PEFTConfig],
                 opt: GradientTransformation, *, mesh=None,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
                 restore: str = "auto", full_finetune: bool = False,
                 seed: int = 0, log_path: Optional[str] = None,
                 fail_at_step: Optional[int] = None,
                 metrics_hook: Optional[Callable[[int, dict], None]] = None):
        self.cfg = cfg
        self.peft = peft
        self.opt = opt
        self.mesh = mesh
        self.full_finetune = full_finetune
        self.ckpt_every = ckpt_every
        self.fail_at_step = fail_at_step
        self.metrics_hook = metrics_hook
        self.log_path = log_path
        self.data_state = DataState()
        self._stop = False
        self._log_f = open(log_path, "a") if log_path else None

        self.ckpt = (CheckpointManager(ckpt_dir) if ckpt_dir else None)
        self.timer = _make_timer()

        step_fn = make_train_step(cfg, peft, opt,
                                  full_finetune=full_finetune)
        if mesh is not None:
            state_sds = abstract_state(cfg, peft, opt,
                                       full_finetune=full_finetune)
            self._st_sh = state_shardings(state_sds, mesh)
            self.step_fn = None       # jit lazily once batch shape is known
            self._raw_step = step_fn
        else:
            self._st_sh = None
            self.step_fn = jax.jit(step_fn, donate_argnums=(0,))
            self._raw_step = step_fn

        # ---- init or restore ----
        restored = False
        if self.ckpt and restore == "auto" and \
                latest_step(self.ckpt.root) is not None:
            state_sds = abstract_state(cfg, peft, opt,
                                       full_finetune=full_finetune)
            tree, extra = self.ckpt.restore(template=state_sds,
                                            shardings=self._st_sh)
            self.state = tree
            self.data_state = DataState.from_dict(extra["data"])
            restored = True
        if not restored:
            self.state = self._init_state(seed)

        signal.signal(signal.SIGTERM, self._preempt)
        try:
            signal.signal(signal.SIGINT, self._preempt)
        except ValueError:            # non-main thread (tests)
            pass

    def _init_state(self, seed):
        rng = jax.random.PRNGKey(seed)
        if self.mesh is None:
            return init_state(rng, self.cfg, self.peft, self.opt,
                              full_finetune=self.full_finetune)
        with mesh_context(MeshContext(self.mesh)):
            init = jax.jit(
                lambda r: init_state(r, self.cfg, self.peft, self.opt,
                                     full_finetune=self.full_finetune),
                out_shardings=self._st_sh)
            return init(rng)

    # ------------------------------------------------------------------ api
    @property
    def step(self) -> int:
        return int(self.state["step"])

    def _preempt(self, signum, frame):
        self._stop = True

    def _jit_for_batch(self, batch):
        if self.step_fn is not None:
            return
        b_sh = batch_shardings(
            jax.tree_util.tree_map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch),
            self.mesh)
        self.step_fn = jax.jit(self._raw_step,
                               in_shardings=(self._st_sh, b_sh),
                               out_shardings=(self._st_sh, None),
                               donate_argnums=(0,))

    def save(self, *, block: bool = False):
        if not self.ckpt:
            return
        self.ckpt.save(self.step, self.state,
                       extra={"data": self.data_state.to_dict()},
                       block=block)

    def fit(self, stream, *, steps: int) -> dict:
        """Run ``steps`` optimizer steps from the stream's cursor."""
        ctx = (mesh_context(MeshContext(self.mesh)) if self.mesh is not None
               else _null_ctx())
        last_metrics: dict = {}
        with ctx:
            while self.step < steps and not self._stop:
                batch_np = stream.batch_at(self.data_state.step)
                self._jit_for_batch(batch_np)
                batch = batch_np
                self.timer.start()
                self.state, metrics = self.step_fn(self.state, batch)
                metrics = {k: float(v) for k, v in metrics.items()}
                dt = self.timer.stop(self.step)
                self.data_state.step += 1
                last_metrics = dict(metrics, step=self.step, step_time=dt)
                self._log(last_metrics)
                if self.metrics_hook:
                    self.metrics_hook(self.step, last_metrics)
                if self.fail_at_step is not None \
                        and self.step == self.fail_at_step:
                    raise RuntimeError(
                        f"injected failure at step {self.step}")
                if self.ckpt and self.step % self.ckpt_every == 0:
                    self.save()
        if self.ckpt:
            self.save(block=True)
            self.ckpt.wait()
        return last_metrics

    def _log(self, metrics: dict):
        if self._log_f:
            self._log_f.write(json.dumps(metrics) + "\n")
            self._log_f.flush()


def _make_timer():
    from repro.runtime.straggler import StepTimer
    return StepTimer(on_straggler=lambda step, dt, mean: print(
        f"[straggler] step {step}: {dt:.3f}s vs mean {mean:.3f}s",
        flush=True))


class _null_ctx:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False
