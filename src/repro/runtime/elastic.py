"""Elastic scaling: rebuild the mesh for whatever devices survive and
re-shard state onto it.

The pieces that make this cheap in this framework:
* checkpoints are logical (path → full array), so restoring onto a new
  mesh is just device_put with fresh shardings (checkpoint/manager.py);
* the data cursor is a single integer (data/pipeline.py), valid for any
  host count;
* sharding rules are functions of (path, shape, mesh axes), not baked
  layouts (parallel/sharding.py).

So "elastic restart" = best_mesh_shape(n_alive) → make mesh → restore.
"""

from __future__ import annotations

import math
from typing import Optional

import jax


def best_mesh_shape(n_devices: int, *, prefer_model: int = 16
                    ) -> tuple[int, int]:
    """(data, model) factorization: model axis as close to prefer_model
    as divisibility allows, remainder to data."""
    model = math.gcd(n_devices, prefer_model)
    for m in range(min(prefer_model, n_devices), 0, -1):
        if n_devices % m == 0:
            model = m
            break
    return n_devices // model, model


def remesh(n_devices: Optional[int] = None, *, prefer_model: int = 16):
    """Build the largest healthy (data, model) mesh."""
    devs = jax.devices()
    n = n_devices or len(devs)
    data, model = best_mesh_shape(n, prefer_model=prefer_model)
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=devs[:data * model])
