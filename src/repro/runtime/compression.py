"""Gradient compression for DP sync: int8 quantization + error feedback.

Mechanism (1-bit-Adam family): quantize g+e to int8 with a per-tensor
scale, all-reduce the int8 payload (8·less ICI bytes), dequantize, and
carry the quantization error e into the next step — provably convergent
for SGD-type methods (Karimireddy et al., 2019).

Use case boundary (measured in bench): ETHER-PEFT grads are ~0.1–1 MB —
DP sync is never the bottleneck, so compression is OFF by default for
PEFT and intended for the full-finetune mode, where DP gradient bytes =
model size. ``compressed_psum`` is the shard_map building block; the
trainer wires it when --grad-compress is set on a pure-DP mesh.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def init_ef_state(grads_tree) -> Any:
    """Zero error-feedback residuals, same structure as grads."""
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32)
        if jnp.issubdtype(jnp.asarray(g).dtype, jnp.floating) else g,
        grads_tree)


def ef_int8_compress(g: jax.Array, err: jax.Array):
    """(g, err) → (q int8, scale, new_err). Per-tensor symmetric scale."""
    gf = g.astype(jnp.float32) + err
    scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, gf - deq


def ef_int8_decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(g: jax.Array, err: jax.Array, axis_name: str):
    """Error-feedback int8 all-reduce over ``axis_name`` (inside
    shard_map). Returns (mean-reduced g, new_err).

    A *shared* scale (pmax of per-device maxima — one scalar collective)
    is agreed before quantizing so the int32-summed payload dequantizes
    exactly; per-device scales cannot be mixed after summation. Error
    per element ≤ scale/2.
    """
    gf = g.astype(jnp.float32) + err
    local_max = jnp.max(jnp.abs(gf))
    scale = jax.lax.pmax(local_max, axis_name) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_err = gf - q.astype(jnp.float32) * scale
    summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return (summed.astype(jnp.float32) * scale / n).astype(g.dtype), new_err


def tree_compressed_psum(grads, err_tree, axis_name: str):
    """compressed_psum over a whole gradient tree."""
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(err_tree)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        if jnp.issubdtype(g.dtype, jnp.floating):
            g2, e2 = compressed_psum(g, e, axis_name)
        else:
            g2, e2 = g, e
        out_g.append(g2)
        out_e.append(e2)
    return (jax.tree_util.tree_unflatten(tdef, out_g),
            jax.tree_util.tree_unflatten(tdef, out_e))
