"""Quickstart: ETHER in five minutes on CPU.

    PYTHONPATH=src python examples/quickstart.py

1. builds a small llama-family model, pretrains it briefly,
2. adapts it to a shifted task with ETHER at three learning rates
   spanning two orders of magnitude (the paper's LR-robustness claim),
3. merges the adapter into the base weights and verifies zero-latency
   serving is bit-identical,
4. prints the parameter-efficiency table for all methods.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, peft_targets
from repro.core.peft import (adapters_param_count, init_adapters,
                             merge_params)
from repro.core.transforms import PEFTConfig
from repro.data.pipeline import SyntheticLMStream
from repro.models import init_model, prefill, train_loss
from repro.optim import adamw, apply_updates, constant

ARCH = "smollm-360m"


def train(params, cfg, peft, stream, lr, steps):
    adapters = init_adapters(jax.random.PRNGKey(1), params, peft)
    opt = adamw(constant(lr))
    state = opt.init(adapters) if peft else opt.init(params)

    @jax.jit
    def step(tr, s, b):
        if peft is None:
            (l, _), g = jax.value_and_grad(
                lambda p: train_loss(p, None, b, cfg, None),
                has_aux=True)(tr)
        else:
            (l, _), g = jax.value_and_grad(
                lambda a: train_loss(params, a, b, cfg, peft),
                has_aux=True)(tr)
        u, s = opt.update(g, s, tr)
        return apply_updates(tr, u), s, l

    tr = adapters if peft else params
    for i in range(steps):
        tr, state, loss = step(tr, state, stream.batch_at(i))
    return tr, float(loss)


def main():
    cfg = get_config(ARCH, "smoke")
    print(f"model: {cfg.name} ({cfg.n_layers}L d={cfg.d_model})")

    # 1. pretrain on task A
    params = init_model(jax.random.PRNGKey(0), cfg)
    stream_a = SyntheticLMStream(vocab=cfg.vocab, batch=8, seq_len=32,
                                 seed=0)
    params, loss = train(params, cfg, None, stream_a, 2e-3, 80)
    print(f"pretrained on task A: loss={loss:.3f}")

    # 2. ETHER-adapt to task B across LR magnitudes
    stream_b = SyntheticLMStream(vocab=cfg.vocab, batch=8, seq_len=32,
                                 seed=777)
    peft = PEFTConfig(method="ether", n_blocks=4,
                      targets=peft_targets(ARCH))
    print(f"\nETHER adaptation ({adapters_param_count(params, peft)} "
          "trainable params):")
    adapters = None
    for lr in (2e-3, 2e-2, 2e-1):
        adapters, loss = train(params, cfg, peft, stream_b, lr, 50)
        print(f"  lr={lr:<6g} final task-B loss={loss:.3f}  "
              "(stable across magnitudes — paper Figs. 5/6)")

    # 3. merge & verify zero-latency serving
    batch = stream_b.batch_at(0)
    _, logits_a = prefill(params, adapters, batch, cfg, peft)
    merged = merge_params(params, adapters, peft)
    _, logits_m = prefill(merged, None, batch, cfg, None)
    err = float(jnp.abs(logits_a - logits_m).max())
    print(f"\nmerged-serving max |Δlogits| = {err:.2e} (exact absorption)")

    # 4. parameter-efficiency table
    print("\ntrainable parameters by method (same targets):")
    for m in ("ether", "etherplus", "lora", "oft", "naive"):
        p = PEFTConfig(method=m, n_blocks=4, rank=8,
                       targets=peft_targets(ARCH))
        print(f"  {m:10s} {adapters_param_count(params, p):>10,}")


if __name__ == "__main__":
    main()
