"""End-to-end driver (deliverable b): train a ~106M-parameter llama-family
model for a few hundred steps through the full production stack —
Trainer (async checkpoints, auto-resume, straggler watchdog), data
pipeline, then ETHER-adapt the pretrained base to a shifted task.

    PYTHONPATH=src python examples/train_100m.py \
        --pretrain-steps 200 --adapt-steps 100 --out /tmp/run100m

CPU note: ~106M params × 1k tokens/step ≈ 4e11 FLOPs/step — expect tens
of seconds per step on one core; use --quick for a 2-minute sanity pass.
"""

import argparse
import dataclasses
import json
import os

from repro.configs._common import SMOKE
from repro.core.transforms import PEFTConfig
from repro.data.pipeline import SyntheticLMStream
from repro.models import ModelConfig
from repro.optim import adamw, cosine, constant
from repro.runtime.trainer import Trainer


def model_100m(quick=False):
    if quick:
        return ModelConfig(name="quick-12m", n_layers=4, d_model=256,
                           n_heads=4, n_kv=2, d_ff=768, vocab=8192,
                           **SMOKE)
    # ~101M params; vocab sized so the synthetic next-token structure is
    # learnable within a few hundred CPU steps (32k vocab needs far more
    # token-identity exposure than a 300-step run provides — measured).
    return ModelConfig(name="lm-101m", n_layers=14, d_model=768,
                       n_heads=12, n_kv=6, d_ff=2304, vocab=8192,
                       **SMOKE)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pretrain-steps", type=int, default=200)
    ap.add_argument("--adapt-steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--adapt-lr", type=float, default=2e-2)
    ap.add_argument("--out", default="/tmp/run100m")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--backend", default="jnp",
                    choices=("jnp", "pallas", "auto"),
                    help="execution backend for the ETHER hot ops; "
                         "'auto' is kernel-backed both directions "
                         "(compiled on TPU, interpret-mode emulation "
                         "elsewhere — slow on CPU, but the counters "
                         "prove the path)")
    args = ap.parse_args()

    cfg = model_100m(args.quick)
    os.makedirs(args.out, exist_ok=True)
    from repro.common.pytree import tree_count
    import jax
    from repro.models import init_model
    n = tree_count(jax.eval_shape(
        lambda: init_model(jax.random.PRNGKey(0), cfg)))
    print(f"model {cfg.name}: {n/1e6:.1f}M params", flush=True)

    # ---- phase 1: pretrain (full finetuning path) ----
    stream = SyntheticLMStream(vocab=cfg.vocab, batch=args.batch,
                               seq_len=args.seq_len, seed=0)
    tr = Trainer(cfg, None, adamw(cosine(args.lr, args.pretrain_steps,
                                         warmup=30)),
                 full_finetune=True, ckpt_dir=os.path.join(args.out, "pre"),
                 ckpt_every=20, log_path=os.path.join(args.out,
                                                      "pretrain.jsonl"))
    m = tr.fit(stream, steps=args.pretrain_steps)
    print(f"pretrain done @ step {tr.step}: {m}", flush=True)
    base_params = tr.state["params"]

    # ---- phase 2: ETHER adaptation of the pretrained base ----
    peft = PEFTConfig(method="ether", n_blocks=32,
                      targets="q_proj|k_proj|v_proj|o_proj|gate_proj"
                              "|up_proj|down_proj", backend=args.backend)
    from repro.core import execute
    execute.reset_counters()
    step_times: list = []
    tr2 = Trainer(cfg, peft, adamw(constant(args.adapt_lr)),
                  ckpt_dir=os.path.join(args.out, "adapt"), ckpt_every=20,
                  log_path=os.path.join(args.out, "adapt.jsonl"),
                  metrics_hook=lambda s, mt: step_times.append(
                      mt["step_time"]))
    tr2.state["params"] = base_params        # adapt the pretrained base
    stream_b = SyntheticLMStream(vocab=cfg.vocab, batch=args.batch,
                                 seq_len=args.seq_len, seed=777)
    m2 = tr2.fit(stream_b, steps=args.adapt_steps)
    print(f"ETHER adaptation done @ step {tr2.step}: {m2}", flush=True)

    # kernel-path visibility: what the adaptation phase actually traced
    # (fwd AND bwd — *_bwd.pallas > 0 means training ran hand-derived
    # Pallas backwards, *_bwd.jnp would mean ref-AD fallback) and what a
    # step costs once jit is warm.
    fwd_c, bwd_c = execute.counters("fwd"), execute.counters("bwd")
    steady = step_times[1:] or step_times    # step 0 includes jit
    per_step = sum(steady) / max(len(steady), 1)
    first = f"(first step {step_times[0]:.3f}s incl. jit)" \
        if step_times else "(no adapt steps ran)"
    print(f"[adapt] backend={args.backend}  per-step wall time "
          f"{per_step:.3f}s {first}", flush=True)
    print(f"[adapt] execute counters fwd: {fwd_c or '{}'}", flush=True)
    print(f"[adapt] execute counters bwd: {bwd_c or '{}'}", flush=True)

    summary = {"params_m": n / 1e6, "pretrain": m, "adapt": m2,
               "backend": args.backend, "adapt_step_time_s": per_step,
               "execute_counters": {"fwd": fwd_c, "bwd": bwd_c},
               "anomalous_steps": tr.timer.anomalies + tr2.timer.anomalies}
    with open(os.path.join(args.out, "summary.json"), "w") as f:
        json.dump(summary, f, indent=1)
    print(json.dumps(summary, indent=1))


if __name__ == "__main__":
    main()
