"""Fault-tolerance walkthrough: failure injection → auto-resume, then an
elastic restart on a smaller mesh (simulating dead hosts).

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/elastic_restart.py
"""

import os
import tempfile

import jax

from repro.configs import get_config, peft_targets
from repro.core.transforms import PEFTConfig
from repro.data.pipeline import SyntheticLMStream
from repro.launch.mesh import make_host_mesh
from repro.optim import adamw, constant
from repro.runtime.trainer import Trainer


def main():
    n_dev = len(jax.devices())
    cfg = get_config("smollm-360m", "smoke")
    peft = PEFTConfig(method="ether", n_blocks=4,
                      targets=peft_targets("smollm-360m"))
    stream = SyntheticLMStream(vocab=cfg.vocab, batch=8, seq_len=32,
                               seed=0)
    ckpt = tempfile.mkdtemp(prefix="elastic_")
    mesh = make_host_mesh(max(n_dev // 2, 1), min(2, n_dev)) \
        if n_dev >= 4 else None
    print(f"devices={n_dev}; initial mesh="
          f"{dict(mesh.shape) if mesh else 'single-device'}")

    # run 1: crash at step 15 (checkpoint every 10)
    tr = Trainer(cfg, peft, adamw(constant(1e-2)), mesh=mesh,
                 ckpt_dir=ckpt, ckpt_every=10, fail_at_step=15)
    try:
        tr.fit(stream, steps=40)
    except RuntimeError as e:
        print(f"run 1 died as injected: {e}")

    # run 2: "two hosts died" — rebuild a smaller mesh, auto-restore the
    # logical checkpoint onto it, finish training
    from repro.runtime.elastic import best_mesh_shape
    if n_dev >= 4:
        d2, m2 = best_mesh_shape(n_dev // 2, prefer_model=2)
        mesh2 = make_host_mesh(d2, m2)
        print(f"elastic restart on mesh {dict(mesh2.shape)} "
              f"({n_dev}→{n_dev // 2} devices)")
    else:
        mesh2 = None
    tr2 = Trainer(cfg, peft, adamw(constant(1e-2)), mesh=mesh2,
                  ckpt_dir=ckpt, ckpt_every=10)
    print(f"restored at step {tr2.step} "
          f"(data cursor {tr2.data_state.step})")
    m = tr2.fit(stream, steps=40)
    print(f"finished @ step {tr2.step}: loss={m['loss']:.3f}")
    print(f"straggler log: {tr2.timer.anomalies}")


if __name__ == "__main__":
    main()
