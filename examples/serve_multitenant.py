"""Multi-tenant ETHER serving through the continuous-batching engine.

ETHER adapters are so small (O(L·d)) that a fixed-capacity device bank
of per-client adapters costs a few KB per tenant; requests carry a
tenant id, the registry maps it to a bank slot (onboarding brand-new
tenants mid-traffic with a functional one-row swap), and the engine's
fused batched decode gathers each sequence's hyperplanes on the fly —
no weight swapping, no per-tenant batches, no recompiles (contrast
with multi-LoRA serving which must fit r×(d+f) per tenant).

With ``--merged-capacity N`` (default 2) the registry additionally runs
the two-tier policy (DESIGN.md §11): tenants that dominate the Zipf
traffic get their reflection absorbed into cached merged weights and
are served reflection-free whenever a decode step's active slots all
belong to one hot tenant; everyone else stays on the gather-and-reflect
bank.  The isolation check is tier-faithful (``oracle_tokens``).

``--arch`` picks the decoder family: attention (smollm-360m) serves via
causal pad masking, Mamba-2 and RecurrentGemma via pad-invariant
recurrent prefill (per-slot SSM/RG-LRU state, DESIGN.md §10).

``--deadline-ms`` stamps per-request SLOs (replayed on the real clock);
``--chaos-seed`` injects a seeded fault plan — corrupted adapters,
kernel raises, merge failures, stragglers, eviction storms — and the
report shows the split failure accounting (DESIGN.md §12).

    PYTHONPATH=src python examples/serve_multitenant.py --tenants 64
    PYTHONPATH=src python examples/serve_multitenant.py \
        --arch mamba2-1.3b --tenants 32
"""

import argparse
import copy

import jax

from repro.configs import get_config, peft_targets
from repro.core.peft import AdapterBank, validate_tenant_ids
from repro.core.transforms import PEFTConfig
from repro.models import init_model
from repro.serving import (AdapterRegistry, AdapterStore, FaultPlan,
                           Journal, Scheduler, ServeEngine, oracle_tokens,
                           recover, summarize, synthetic_workload)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m",
                    choices=("smollm-360m", "mamba2-1.3b",
                             "recurrentgemma-9b"))
    ap.add_argument("--tenants", type=int, default=64,
                    help="tenant universe; the device bank holds 1/4")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--method", default="ether",
                    choices=AdapterBank.BANK_METHODS)
    ap.add_argument("--backend", default="auto")
    ap.add_argument("--merged-capacity", type=int, default=2,
                    help="hot-tier merged-weight entries (0 = tierless)")
    ap.add_argument("--zipf-a", type=float, default=1.5,
                    help="tenant popularity skew (skewed traffic "
                         "exercises hot-tenant promotion)")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-request total SLO deadline in ms (0 = "
                         "none; deadlines need the real clock, so this "
                         "switches the replay off saturation mode)")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="inject a seeded FaultPlan (all fault classes, "
                         "DESIGN.md §12); the report adds failure "
                         "accounting with typed outcomes")
    ap.add_argument("--journal-dir", default="",
                    help="crash-safe serving: durable adapter store + "
                         "write-ahead request journal (DESIGN.md §13)")
    ap.add_argument("--restore", action="store_true",
                    help="warm restart from --journal-dir: resume "
                         "in-flight requests, replay the rest")
    ap.add_argument("--mesh", default="",
                    help="dp,tp device mesh (e.g. 2,2): tensor-sharded "
                         "backbone/bank over tp, replica-parallel slot "
                         "groups over dp (DESIGN.md §14); pair with "
                         "--fake-devices off-TPU")
    ap.add_argument("--fake-devices", type=int, default=0,
                    help="force N fake CPU host devices (set before the "
                         "first backend touch)")
    args = ap.parse_args()

    if args.fake_devices:
        import os
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count="
              f"{args.fake_devices}")

    cfg = get_config(args.arch, "smoke")
    rng = jax.random.PRNGKey(0)
    params = init_model(rng, cfg)
    peft = PEFTConfig(method=args.method, n_blocks=4,
                      targets=peft_targets(args.arch),
                      backend=args.backend)
    # windowed hybrids: keep bucket + gen inside the attention window
    # (ring wrap is rejected at engine construction); the smoke
    # RecurrentGemma window is 16
    window = getattr(cfg, "window", None)
    bucket = 16 if window is None else min(16, window - args.gen)
    if bucket < 4:
        raise SystemExit(f"--gen {args.gen} leaves no room inside the "
                         f"attention window {window}")

    faults = None
    if args.chaos_seed is not None:
        faults = FaultPlan.sample(args.chaos_seed, n_steps=32,
                                  tenants=args.tenants)
    store = journal = None
    if args.journal_dir:
        import os
        store = AdapterStore(os.path.join(args.journal_dir, "adapters"),
                             faults=faults)
        journal = Journal(os.path.join(args.journal_dir,
                                       "journal.jsonl"),
                          fsync_every=1, faults=faults)
    elif args.restore:
        raise SystemExit("--restore requires --journal-dir")
    capacity = max(2, args.tenants // 4)
    registry = AdapterRegistry(params, peft, capacity,
                               n_tenants=args.tenants,
                               rng=jax.random.fold_in(rng, 1),
                               merged_capacity=args.merged_capacity,
                               promote_after=2, window=16, min_dwell=4,
                               faults=faults, store=store,
                               journal=journal)
    kb = registry.bank.size_bytes() / 1e3
    print(f"adapter bank: capacity {capacity} of {args.tenants} tenants "
          f"= {kb:.1f} KB HBM ({kb / capacity:.2f} KB/tenant)")

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_host_mesh
        dp, tp = (int(x) for x in args.mesh.split(","))
        mesh = make_host_mesh(dp, tp)
        print(f"mesh {dp}x{tp}: backbone/bank sharded over {tp}-way "
              f"model axis, {dp} replica-parallel slot groups")
    engine = ServeEngine(cfg, params, registry, peft, slots=args.slots,
                         prompt_buckets=(bucket,),
                         max_new_tokens=args.gen, faults=faults,
                         journal=journal, mesh=mesh)
    report = None
    if args.restore:
        # warm restart BEFORE warmup: membership rebuilt from the
        # journal, resume buckets registered for compilation
        report = recover(journal, registry, engine)
        print(f"warm restart: {len(report.resume)} in-flight resumed, "
              f"{len(report.completed) + len(report.failed)} journaled "
              f"terminals adopted, membership {report.membership}")
    snap = engine.warmup()

    # a malformed tenant id raises at the frontend instead of silently
    # clamping to the last tenant inside the device gather
    try:
        validate_tenant_ids([args.tenants + 7], args.tenants)
    except ValueError as e:
        print(f"frontend id validation: OK ({e})")

    deadline_s = args.deadline_ms / 1e3 if args.deadline_ms > 0 else None
    workload = synthetic_workload(args.requests, args.tenants,
                                  vocab=cfg.vocab, rate_rps=None,
                                  zipf_a=args.zipf_a,
                                  prompt_lens=(4, bucket),
                                  gen_lens=(2, args.gen), seed=3,
                                  deadline_ttft_s=deadline_s
                                  and deadline_s / 2,
                                  deadline_total_s=deadline_s)
    sched = Scheduler(engine, watchdog_s=10 * deadline_s
                      if deadline_s else None)
    if report is not None:
        journaled = report.journaled_rids()
        workload = [r for r in workload if r.rid not in journaled]
    # deadlines are inert under the inf saturation clock, so a deadline
    # run replays on the real clock instead
    done = sched.run(copy.deepcopy(workload),
                     clock=None if deadline_s else lambda: float("inf"),
                     resume=report.resume if report else ())
    engine.assert_no_retrace(snap)
    s = summarize(done, scheduler=sched)
    print(f"served {s['n_requests']} requests / "
          f"{s.get('generated_tokens', 0)} tokens: "
          f"{s.get('throughput_tok_s', 0.0):.0f} tok/s, "
          f"p50 {s.get('p50_ms_per_token', float('nan')):.2f} ms/token; "
          f"churn: {registry.stats['misses']} onboards, "
          f"{registry.stats['evictions']} evictions, 0 recompiles")
    if deadline_s:
        print(f"SLO attainment: ttft "
              f"{s.get('slo_ttft_attained', 1.0) * 100:.0f}%  total "
              f"{s.get('slo_total_attained', 1.0) * 100:.0f}%")
    acc = sched.accounting()
    if any(acc.values()) or faults is not None:
        print(f"degradation: {acc}"
              + (f"  injected {faults.summary() or '(nothing fired)'}  "
                 f"quarantined {sorted(registry.quarantined())}"
                 if faults is not None else ""))
    if args.merged_capacity:
        t, r = engine.tier_stats, registry.stats
        total = t["merged_tokens"] + t["bank_tokens"]
        print(f"merged tier: {t['merged_tokens']}/{total} tokens "
              f"({t['merged_tokens'] / max(total, 1) * 100:.0f}% hot-tier "
              f"hit rate), {r['promotions']} promotions / "
              f"{r['demotions']} demotions / "
              f"{r['merged_evictions']} merged evictions, "
              f"{r['merge_s'] * 1e3:.2f} ms merging, "
              f"{sched.stats['affinity_admissions']} affinity admissions")

    # per-request isolation: each continuous-batched output equals the
    # same request decoded alone against its own tenant's adapters —
    # tier-faithfully: the oracle replays each request's recorded tier
    # schedule (merged vs gather-and-reflect differ in rounding, so a
    # bank-only replay would be the wrong reference for hot-tier tokens)
    for req in done[:3]:
        assert req.tokens == oracle_tokens(cfg, peft, params, registry,
                                           req), req.rid
    print("per-request isolation verified (engine rows == tier-faithful "
          "single-tenant one-shot runs)")


if __name__ == "__main__":
    main()
