"""Multi-tenant ETHER serving (beyond-paper system feature).

ETHER adapters are so small (O(L·d)) that a bank of thousands of
per-client adapters fits in a few MB of HBM; requests carry an
adapter id and the batched reflection gathers each sequence's
hyperplanes on the fly — no weight swapping, no per-tenant batches
(contrast with multi-LoRA serving which must fit r×(d+f) per tenant).

    PYTHONPATH=src python examples/serve_multitenant.py --tenants 64
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.transforms import reflect_activation_batched
from repro.models import init_model
from repro.models.backbone import forward, logits_fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tenants", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config("smollm-360m", "smoke")
    params = init_model(jax.random.PRNGKey(0), cfg)
    d = cfg.d_model
    n_blocks = 4

    # per-tenant hyperplane banks for the embedding-side reflection
    bank = jax.random.normal(jax.random.PRNGKey(1),
                             (args.tenants, n_blocks, d // n_blocks))
    bank_bytes = bank.size * 4
    print(f"adapter bank: {args.tenants} tenants = {bank_bytes/1e3:.1f} KB "
          f"({bank_bytes/args.tenants:.0f} B/tenant)")

    tokens = jax.random.randint(jax.random.PRNGKey(2),
                                (args.batch, args.seq), 0, cfg.vocab)
    ids = jax.random.randint(jax.random.PRNGKey(3), (args.batch,), 0,
                             args.tenants)

    @jax.jit
    def serve(params, bank, tokens, ids):
        # embed, apply per-request tenant reflection, run the backbone
        from repro.models import layers as L
        x = L.embed(params["embed"], tokens, cfg.cdt())
        x = reflect_activation_batched(x, bank, ids)
        hidden, _, _ = forward(params, cfg, inputs_embeds=x, mode="train")
        return logits_fn(params, cfg, hidden[:, -1:])

    out = serve(params, bank, tokens, ids)
    out.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(5):
        out = serve(params, bank, tokens, ids)
    out.block_until_ready()
    dt = (time.perf_counter() - t0) / 5
    print(f"batched multi-tenant forward: {dt*1e3:.1f} ms "
          f"({args.batch} requests, {args.batch} distinct adapters)")

    # per-request correctness: each row equals its tenant's single run
    import numpy as np
    for b in range(min(3, args.batch)):
        one = serve(params, bank, tokens[b:b + 1], ids[b:b + 1])
        np.testing.assert_allclose(np.asarray(out[b]), np.asarray(one[0]),
                                   rtol=2e-4, atol=2e-4)
    print("per-request isolation verified (rows == single-tenant runs)")


if __name__ == "__main__":
    main()
