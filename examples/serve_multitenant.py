"""Multi-tenant ETHER serving through the continuous-batching engine.

ETHER adapters are so small (O(L·d)) that a fixed-capacity device bank
of per-client adapters costs a few KB per tenant; requests carry a
tenant id, the registry maps it to a bank slot (onboarding brand-new
tenants mid-traffic with a functional one-row swap), and the engine's
fused batched decode gathers each sequence's hyperplanes on the fly —
no weight swapping, no per-tenant batches, no recompiles (contrast
with multi-LoRA serving which must fit r×(d+f) per tenant).

With ``--merged-capacity N`` (default 2) the registry additionally runs
the two-tier policy (DESIGN.md §11): tenants that dominate the Zipf
traffic get their reflection absorbed into cached merged weights and
are served reflection-free whenever a decode step's active slots all
belong to one hot tenant; everyone else stays on the gather-and-reflect
bank.  The isolation check is tier-faithful (``oracle_tokens``).

``--arch`` picks the decoder family: attention (smollm-360m) serves via
causal pad masking, Mamba-2 and RecurrentGemma via pad-invariant
recurrent prefill (per-slot SSM/RG-LRU state, DESIGN.md §10).

    PYTHONPATH=src python examples/serve_multitenant.py --tenants 64
    PYTHONPATH=src python examples/serve_multitenant.py \
        --arch mamba2-1.3b --tenants 32
"""

import argparse
import copy

import jax

from repro.configs import get_config, peft_targets
from repro.core.peft import AdapterBank, validate_tenant_ids
from repro.core.transforms import PEFTConfig
from repro.models import init_model
from repro.serving import (AdapterRegistry, Scheduler, ServeEngine,
                           oracle_tokens, summarize, synthetic_workload)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m",
                    choices=("smollm-360m", "mamba2-1.3b",
                             "recurrentgemma-9b"))
    ap.add_argument("--tenants", type=int, default=64,
                    help="tenant universe; the device bank holds 1/4")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--method", default="ether",
                    choices=AdapterBank.BANK_METHODS)
    ap.add_argument("--backend", default="auto")
    ap.add_argument("--merged-capacity", type=int, default=2,
                    help="hot-tier merged-weight entries (0 = tierless)")
    ap.add_argument("--zipf-a", type=float, default=1.5,
                    help="tenant popularity skew (skewed traffic "
                         "exercises hot-tenant promotion)")
    args = ap.parse_args()

    cfg = get_config(args.arch, "smoke")
    rng = jax.random.PRNGKey(0)
    params = init_model(rng, cfg)
    peft = PEFTConfig(method=args.method, n_blocks=4,
                      targets=peft_targets(args.arch),
                      backend=args.backend)
    # windowed hybrids: keep bucket + gen inside the attention window
    # (ring wrap is rejected at engine construction); the smoke
    # RecurrentGemma window is 16
    window = getattr(cfg, "window", None)
    bucket = 16 if window is None else min(16, window - args.gen)
    if bucket < 4:
        raise SystemExit(f"--gen {args.gen} leaves no room inside the "
                         f"attention window {window}")

    capacity = max(2, args.tenants // 4)
    registry = AdapterRegistry(params, peft, capacity,
                               n_tenants=args.tenants,
                               rng=jax.random.fold_in(rng, 1),
                               merged_capacity=args.merged_capacity,
                               promote_after=2, window=16, min_dwell=4)
    kb = registry.bank.size_bytes() / 1e3
    print(f"adapter bank: capacity {capacity} of {args.tenants} tenants "
          f"= {kb:.1f} KB HBM ({kb / capacity:.2f} KB/tenant)")

    engine = ServeEngine(cfg, params, registry, peft, slots=args.slots,
                         prompt_buckets=(bucket,),
                         max_new_tokens=args.gen)
    snap = engine.warmup()

    # a malformed tenant id raises at the frontend instead of silently
    # clamping to the last tenant inside the device gather
    try:
        validate_tenant_ids([args.tenants + 7], args.tenants)
    except ValueError as e:
        print(f"frontend id validation: OK ({e})")

    workload = synthetic_workload(args.requests, args.tenants,
                                  vocab=cfg.vocab, rate_rps=None,
                                  zipf_a=args.zipf_a,
                                  prompt_lens=(4, bucket),
                                  gen_lens=(2, args.gen), seed=3)
    sched = Scheduler(engine)
    done = sched.run(copy.deepcopy(workload),
                     clock=lambda: float("inf"))
    engine.assert_no_retrace(snap)
    s = summarize(done, dropped=len(sched.dropped))
    print(f"served {s['n_requests']} requests / "
          f"{s['generated_tokens']} tokens: "
          f"{s['throughput_tok_s']:.0f} tok/s, "
          f"p50 {s['p50_ms_per_token']:.2f} ms/token; churn: "
          f"{registry.stats['misses']} onboards, "
          f"{registry.stats['evictions']} evictions, 0 recompiles")
    if args.merged_capacity:
        t, r = engine.tier_stats, registry.stats
        total = t["merged_tokens"] + t["bank_tokens"]
        print(f"merged tier: {t['merged_tokens']}/{total} tokens "
              f"({t['merged_tokens'] / max(total, 1) * 100:.0f}% hot-tier "
              f"hit rate), {r['promotions']} promotions / "
              f"{r['demotions']} demotions / "
              f"{r['merged_evictions']} merged evictions, "
              f"{r['merge_s'] * 1e3:.2f} ms merging, "
              f"{sched.stats['affinity_admissions']} affinity admissions")

    # per-request isolation: each continuous-batched output equals the
    # same request decoded alone against its own tenant's adapters —
    # tier-faithfully: the oracle replays each request's recorded tier
    # schedule (merged vs gather-and-reflect differ in rounding, so a
    # bank-only replay would be the wrong reference for hot-tier tokens)
    for req in done[:3]:
        assert req.tokens == oracle_tokens(cfg, peft, params, registry,
                                           req), req.rid
    print("per-request isolation verified (engine rows == tier-faithful "
          "single-tenant one-shot runs)")


if __name__ == "__main__":
    main()
